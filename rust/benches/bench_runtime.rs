//! Runtime benches. The native section (fused dequant-matmul vs
//! unpack-then-matmul, native forward latency) is fully self-contained;
//! the pipeline section needs artifacts (notice + skip otherwise); the
//! PJRT kernel section additionally needs the `xla` feature.
//! These regenerate the latency/throughput side of every paper exhibit
//! and the native-vs-PJRT comparison axis.
//!
//! Flags (after `--` under `cargo bench`):
//!   --json             write every section's measurements as the
//!                      versioned `nsds.bench` schema to
//!                      `BENCH_runtime.json` at the repo root (then
//!                      re-parse + validate it, failing loudly on a
//!                      schema mismatch — CI's gate)
//!   --quick            ~25x shorter measurement target and reduced
//!                      prefill lengths: the CI smoke mode (plumbing
//!                      check, not stable numbers)
//!   --baseline <path>  diff this run's decode/prefill sections against
//!                      a committed `nsds.bench` baseline and exit
//!                      nonzero on a >2x median regression (notice +
//!                      skip when the file doesn't exist yet)

#[path = "harness.rs"]
mod harness;

use harness::{bench, black_box};
use nsds::allocate::allocate_kv_bits;
use nsds::infer::{fused_gemm_small, fused_matmul, fused_vecmat,
                  generate_batch, generate_batch_spec, BatchEngine,
                  Executor, GenConfig, GenEvent, GenSink, KvCache,
                  KvCachePool, ModelRef, NativeEngine, PackedMatrix,
                  QuantizedModel, SpecDecode, PREFILL_CHUNK};
use nsds::model::{ModelConfig, Weights};
use nsds::quant::{rtn, Backend, QuantSpec, DEFAULT_GROUP};
use nsds::runtime::{Manifest, ModelEntry};
use nsds::sensitivity::{nsds_layer_scores, NsdsOptions};
use nsds::tensor::matmul::matmul;
use nsds::tensor::Tensor;
use nsds::util::pool::default_workers;
use nsds::util::rng::Rng;

/// The unpack-then-matmul baseline the fused kernel must beat:
/// unpack codes + materialize the f32 weight (`PackedMatrix::dequantize`
/// does exactly that), then `tensor::matmul`.
fn unpack_then_matmul(x: &Tensor, pm: &PackedMatrix) -> Tensor {
    matmul(x, &pm.dequantize())
}

fn native_section() {
    let workers = default_workers();
    let mut rng = Rng::new(5);
    println!("== native fused dequant-matmul vs unpack-then-matmul \
              (workers={workers}) ==");
    for bits in [2u8, 4] {
        let (m, k, n, g) = (256usize, 256usize, 256usize, 64usize);
        let w = Tensor::randn(vec![k, n], &mut rng);
        let x = Tensor::randn(vec![m, k], &mut rng);
        let q = rtn::quantize(&w, QuantSpec::new(bits, g));
        let pm = PackedMatrix::from_quantized(&q);
        let fused = bench(
            &format!("fused dequant-matmul {bits}bit {m}x{k}x{n}"),
            || {
                black_box(fused_matmul(&x, &pm, workers));
            },
        );
        let baseline = bench(
            &format!("unpack-then-matmul  {bits}bit {m}x{k}x{n}"),
            || {
                black_box(unpack_then_matmul(&x, &pm));
            },
        );
        println!("  -> fused speedup {bits}bit: {:.2}x",
                 baseline.median_ns / fused.median_ns);
    }

    // The two non-GEMM members of the fused kernel family at their
    // serving shapes: single-row decode (vecmat) and the small decode
    // batch (gemm_small) — the per-step hot paths the LUT micro-kernels
    // target.
    println!("== fused kernel family micro-benches (decode shapes) ==");
    for bits in [2u8, 4] {
        let (k, n, g) = (1024usize, 1024usize, 64usize);
        let w = Tensor::randn(vec![k, n], &mut rng);
        let q = rtn::quantize(&w, QuantSpec::new(bits, g));
        let pm = PackedMatrix::from_quantized(&q);
        let x1 = Tensor::randn(vec![1, k], &mut rng);
        bench(&format!("fused_vecmat {bits}bit 1x{k}x{n}"), || {
            black_box(fused_vecmat(x1.data(), &pm));
        });
        let xb = Tensor::randn(vec![8, k], &mut rng);
        bench(&format!("fused_gemm_small {bits}bit 8x{k}x{n}"), || {
            black_box(fused_gemm_small(&xb, &pm, workers));
        });
    }

    println!("== native forward latency (synthetic llama-s shape) ==");
    let cfg = ModelConfig::llama_s_synth();
    let entry = ModelEntry::synthetic(cfg.clone());
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let bits = vec![4u8; cfg.n_layers];
    let qm = QuantizedModel::quantize(&cfg, &fp, &bits, DEFAULT_GROUP,
                                      Backend::Hqq, None, workers);
    let exec = NativeEngine::new();
    let b = 4;
    let tokens: Vec<i32> =
        (0..b * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
    bench(&format!("native fwd dense [{b}x{}]", cfg.seq), || {
        black_box(exec.forward(&entry, &tokens, b, &fp).unwrap());
    });
    bench(&format!("native fwd packed-4bit [{b}x{}]", cfg.seq), || {
        black_box(
            exec.forward_packed(&entry, &tokens, b, &qm).unwrap());
    });
}

/// KV-cached decode benches: per-token `decode_step` cost at several
/// prefix lengths (must be ~flat — the whole point of the cache: the
/// full-sequence forward's per-token cost grows with the prefix), plus
/// prefill-vs-decode throughput for the dense and fused-packed paths.
fn decode_section() {
    let cfg = ModelConfig::llama_s_synth();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(6);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let bits = vec![4u8; cfg.n_layers];
    let qm = QuantizedModel::quantize(&cfg, &fp, &bits, DEFAULT_GROUP,
                                      Backend::Rtn, None,
                                      default_workers());
    let exec = NativeEngine::new();

    println!("== KV-cached decode_step vs prefix length ==");
    // Each measured iteration clones the prefilled cache once and runs
    // STEPS decode steps, so the constant clone cost is amortized 8x and
    // cannot mask a decode_step that secretly scales with the prefix.
    const STEPS: usize = 8;
    for (label, model) in [("dense", ModelRef::Dense(&fp)),
                           ("packed-4bit", ModelRef::Packed(&qm))] {
        let prefixes = [8usize, 32, 48]; // prefix + STEPS <= cap
        let mut per_tok = Vec::new();
        for &prefix in &prefixes {
            let mut cache = KvCache::for_model(&cfg, cfg.seq);
            for i in 0..prefix {
                model
                    .decode_step(&exec, &entry, &mut cache,
                                 (i % cfg.vocab) as i32)
                    .unwrap();
            }
            let r = bench(
                &format!("decode {STEPS} steps {label} prefix={prefix}"),
                || {
                    let mut c = cache.clone();
                    for j in 0..STEPS {
                        black_box(
                            model
                                .decode_step(&exec, &entry, &mut c,
                                             (j % cfg.vocab) as i32)
                                .unwrap(),
                        );
                    }
                },
            );
            per_tok.push(r.median_ns / STEPS as f64);
        }
        println!(
            "  -> {label} per-token cost, prefix {} vs {}: {:.2}x \
             (prefix-length-independent ≈ 1)",
            prefixes[2], prefixes[0], per_tok[2] / per_tok[0]
        );
    }

    println!("== prefill (full forward) vs decode throughput \
              ({} tokens, dense) ==", cfg.seq);
    let tokens: Vec<i32> =
        (0..cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
    let pre = bench(&format!("prefill fwd [1x{}]", cfg.seq), || {
        black_box(exec.forward(&entry, &tokens, 1, &fp).unwrap());
    });
    let dec = bench(&format!("decode {} steps", cfg.seq), || {
        let mut c = KvCache::for_model(&cfg, cfg.seq);
        for &t in &tokens {
            black_box(exec.decode_step(&entry, &mut c, t, &fp).unwrap());
        }
    });
    let tok_s = |ns: f64| cfg.seq as f64 / (ns / 1e9);
    println!("  -> prefill {:.0} tok/s vs decode {:.0} tok/s",
             tok_s(pre.median_ns), tok_s(dec.median_ns));
}

/// Continuous-batching decode: per-token cost vs batch size. The packed
/// path is the headline — the fused small-batch GEMM dequantizes each
/// weight group once per STEP, so per-token dequant + weight traffic is
/// divided by the number of concurrently decoding sequences and
/// tokens/s must scale with B. The dense path shares weight reads too
/// (one stacked GEMM per projection), just without the dequant term.
fn batch_decode_section() {
    let cfg = ModelConfig::llama_s_synth();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(7);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let bits = vec![4u8; cfg.n_layers];
    let qm = QuantizedModel::quantize(&cfg, &fp, &bits, DEFAULT_GROUP,
                                      Backend::Rtn, None,
                                      default_workers());
    let exec = NativeEngine::new();

    println!("== continuous-batching decode: per-token cost vs batch \
              size ==");
    const STEPS: usize = 8;
    let prefix = 16usize; // prefix + STEPS <= cap for exact decode
    for (label, model) in [("dense", ModelRef::Dense(&fp)),
                           ("packed-4bit", ModelRef::Packed(&qm))] {
        let batches = [1usize, 2, 4, 8];
        let mut per_tok = Vec::new();
        for &b in &batches {
            // B prefilled sequences in one pool.
            let mut pool = KvCachePool::for_model(&cfg, b);
            let slots: Vec<usize> =
                (0..b).map(|_| pool.admit(cfg.seq).unwrap()).collect();
            for i in 0..prefix {
                let active: Vec<(usize, i32)> = slots
                    .iter()
                    .map(|&s| (s, ((i + s) % cfg.vocab) as i32))
                    .collect();
                model
                    .decode_batch(&exec, &entry, &mut pool, &active)
                    .unwrap();
            }
            // The timed closure mutates the prefilled pool directly (no
            // per-iteration clone — its cost scales with B and would
            // bias the B-scaling comparison): positions keep advancing
            // and the attention window saturates at `cap`, identically
            // for every B.
            let mut p = pool;
            let r = bench(
                &format!("decode_batch {STEPS} steps {label} B={b}"),
                || {
                    for j in 0..STEPS {
                        let active: Vec<(usize, i32)> = slots
                            .iter()
                            .map(|&s| {
                                (s, ((j + s) % cfg.vocab) as i32)
                            })
                            .collect();
                        black_box(
                            model
                                .decode_batch(&exec, &entry, &mut p,
                                              &active)
                                .unwrap(),
                        );
                    }
                },
            );
            per_tok.push(r.median_ns / (STEPS * b) as f64);
        }
        let b0 = per_tok[0];
        for (&b, &ns) in batches.iter().zip(&per_tok) {
            println!(
                "  -> {label} B={b}: {:.0} ns/token ({:.2}x vs B=1, \
                 {:.0} tok/s aggregate)",
                ns, ns / b0, 1e9 / ns
            );
        }
        println!(
            "  -> {label} per-token cost B={} vs B=1: {:.2}x \
             (continuous batching amortizes per-step weight traffic)",
            batches[batches.len() - 1],
            per_tok[per_tok.len() - 1] / b0
        );
    }
}

/// Chunked vs per-token prefill: tokens/s and time-to-first-token at
/// several prompt lengths, dense + packed. Chunked prefill pushes whole
/// prompt windows through the multi-row kernels (one projection GEMM —
/// one fused dequant per weight group on the packed path — per layer
/// per chunk) and bulk-appends K/V pages; per-token pays a full decode
/// step per prompt token. TTFT here is the whole-prompt prefill latency
/// — the serving stat the chunked path exists to cut, and it should
/// widen with prompt length.
fn prefill_section() {
    let cfg = ModelConfig::llama_s_synth();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(9);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let bits = vec![4u8; cfg.n_layers];
    let qm = QuantizedModel::quantize(&cfg, &fp, &bits, DEFAULT_GROUP,
                                      Backend::Rtn, None,
                                      default_workers());
    let exec = NativeEngine::new();

    println!("== chunked vs per-token prefill (time-to-first-token) ==");
    // Quick mode trims the long prompts: the 1024-token per-token
    // prefill alone would dominate the smoke run.
    let plens: &[usize] =
        if harness::quick() { &[32, 128] } else { &[32, 256, 1024] };
    for (label, model) in [("dense", ModelRef::Dense(&fp)),
                           ("packed-4bit", ModelRef::Packed(&qm))] {
        for &plen in plens {
            let prompt: Vec<i32> =
                (0..plen).map(|i| (i % cfg.vocab) as i32).collect();
            // Each iteration is one whole-prompt prefill into a fresh
            // slot, so median_ns IS the TTFT for that path.
            let per_tok = bench(
                &format!("prefill per-token {label} len={plen}"),
                || {
                    let mut pool = KvCachePool::for_model(&cfg, 1);
                    let s = pool.admit(plen + 1).unwrap();
                    for &t in &prompt {
                        black_box(
                            model
                                .decode_batch(&exec, &entry, &mut pool,
                                              &[(s, t)])
                                .unwrap(),
                        );
                    }
                },
            );
            let chunked = bench(
                &format!("prefill chunked   {label} len={plen}"),
                || {
                    let mut pool = KvCachePool::for_model(&cfg, 1);
                    let s = pool.admit(plen + 1).unwrap();
                    let mut off = 0usize;
                    while off < plen {
                        let n = PREFILL_CHUNK.min(plen - off);
                        black_box(
                            model
                                .prefill_chunk(&exec, &entry, &mut pool,
                                               s, &prompt[off..off + n])
                                .unwrap(),
                        );
                        off += n;
                    }
                },
            );
            let tok_s = |ns: f64| plen as f64 / (ns / 1e9);
            println!(
                "  -> {label} len={plen}: per-token {:.0} tok/s \
                 (TTFT {:.2} ms) vs chunked {:.0} tok/s (TTFT {:.2} \
                 ms) — {:.2}x faster to first token",
                tok_s(per_tok.median_ns),
                per_tok.median_ns / 1e6,
                tok_s(chunked.median_ns),
                chunked.median_ns / 1e6,
                per_tok.median_ns / chunked.median_ns
            );
        }
    }
}

/// Paged KV cache: resident KV bytes vs the old contiguous
/// pre-allocation, shared-prefix residency, and per-token decode cost
/// at matched batch sizes through the block-table gather (pinning that
/// paging/sharing is a memory win, not a decode tax).
fn paged_kv_section() {
    let cfg = ModelConfig::llama_s_synth();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(8);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::new();
    let model = ModelRef::Dense(&fp);
    let b = 8usize;
    let held = 24usize; // tokens actually resident per sequence

    println!("== paged KV cache: resident bytes vs contiguous ==");
    // B slots admitted at the full context capacity but holding only
    // `held` tokens — the serving steady state paging exists for: the
    // contiguous scheme billed worst-case capacity × concurrency.
    let mut pool = KvCachePool::for_model(&cfg, b);
    let slots: Vec<usize> =
        (0..b).map(|_| pool.admit(cfg.seq).unwrap()).collect();
    for i in 0..held {
        let active: Vec<(usize, i32)> = slots
            .iter()
            .map(|&s| (s, ((i + s) % cfg.vocab) as i32))
            .collect();
        model.decode_batch(&exec, &entry, &mut pool, &active).unwrap();
    }
    println!(
        "  -> {b} slots @ cap {} holding {held} tokens each: paged \
         {} KiB vs contiguous {} KiB ({:.2}x smaller)",
        cfg.seq,
        pool.bytes() / 1024,
        pool.contiguous_bytes() / 1024,
        pool.contiguous_bytes() as f64 / pool.bytes() as f64
    );

    // Shared prefix: the other B-1 sequences forked from one resident
    // prompt hold its full pages by reference (tails copied).
    let mut shared = KvCachePool::for_model(&cfg, b);
    let donor = shared.admit(cfg.seq).unwrap();
    for i in 0..held {
        model
            .decode_batch(&exec, &entry, &mut shared,
                          &[(donor, (i % cfg.vocab) as i32)])
            .unwrap();
    }
    for _ in 1..b {
        shared.admit_shared(cfg.seq, donor, held).unwrap();
    }
    shared.check_page_accounting().unwrap();
    println!(
        "  -> {b} slots sharing one {held}-token prefix: {} KiB \
         resident vs {} KiB unshared ({:.2}x smaller)",
        shared.bytes() / 1024,
        pool.bytes() / 1024,
        pool.bytes() as f64 / shared.bytes() as f64
    );

    // Per-token decode cost at a matched batch size over both pools.
    const STEPS: usize = 8;
    for (label, p) in [("private", pool), ("shared-prefix", shared)] {
        let mut p = p;
        let slots: Vec<usize> =
            (0..p.max_slots()).filter(|&s| p.is_active(s)).collect();
        let r = bench(
            &format!("decode_batch {STEPS} steps paged/{label} B={b}"),
            || {
                for j in 0..STEPS {
                    let active: Vec<(usize, i32)> = slots
                        .iter()
                        .map(|&s| (s, ((j + s) % cfg.vocab) as i32))
                        .collect();
                    black_box(
                        model
                            .decode_batch(&exec, &entry, &mut p,
                                          &active)
                            .unwrap(),
                    );
                }
            },
        );
        println!("  -> paged/{label}: {:.0} ns/token",
                 r.median_ns / (STEPS * b) as f64);
    }
}

/// Self-speculative decoding from the quantized zoo: a 2-bit drafter
/// proposing K tokens per step for a 4-bit target that verifies all
/// K + 1 positions in one multi-row pass. Reported per K ∈ {2, 4, 8}:
/// tokens per target pass (`SpecCounters::tokens_per_verify` — the
/// arithmetic-intensity win, > 1 whenever anything is accepted),
/// draft accept rate, and end-to-end generated tok/s vs plain batched
/// decode of the SAME requests (which speculation reproduces
/// bit-identically — the bench asserts it). An identical-drafter row
/// (drafter == target) pins the K + 1 acceptance ceiling the
/// realistic rows are read against.
fn spec_decode_section() {
    let cfg = ModelConfig::llama_s_synth();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(10);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let workers = default_workers();
    let t4 = QuantizedModel::quantize(&cfg, &fp,
                                      &vec![4u8; cfg.n_layers],
                                      DEFAULT_GROUP, Backend::Rtn,
                                      None, workers);
    let d2 = QuantizedModel::quantize(&cfg, &fp,
                                      &vec![2u8; cfg.n_layers],
                                      DEFAULT_GROUP, Backend::Rtn,
                                      None, workers);
    let exec = NativeEngine::new();
    let target = ModelRef::Packed(&t4);
    let drafter = ModelRef::Packed(&d2);

    let b = 4usize;
    let plen = 16usize;
    let max_new = if harness::quick() { 16 } else { 48 };
    let reqs = |k: Option<usize>| -> Vec<(Vec<i32>, GenConfig)> {
        (0..b)
            .map(|i| {
                let prompt: Vec<i32> = (0..plen)
                    .map(|j| ((3 * i + 7 * j) % cfg.vocab) as i32)
                    .collect();
                let gc = GenConfig {
                    max_new,
                    spec: k.map(|k| SpecDecode { k }),
                    ..GenConfig::default()
                };
                (prompt, gc)
            })
            .collect()
    };
    let total_tokens = (b * max_new) as f64;
    let tok_s = |ns: f64| total_tokens / (ns / 1e9);

    println!("== self-speculative decode: 2-bit drafter, 4-bit \
              target, B={b}, {max_new} tokens/request ==");
    let plain_reqs = reqs(None);
    let plain_out =
        generate_batch(&exec, &entry, target, &plain_reqs, b).unwrap();
    let plain = bench("spec plain-decode baseline", || {
        black_box(
            generate_batch(&exec, &entry, target, &plain_reqs, b)
                .unwrap());
    });
    println!("  -> plain batched decode: {:.0} tok/s",
             tok_s(plain.median_ns));

    for k in [2usize, 4, 8] {
        let sreqs = reqs(Some(k));
        // Counters (and the exactness claim) from one engine run
        // outside the timing loop.
        let mut e: BatchEngine<usize> = BatchEngine::new(&cfg, b);
        for (i, (p, gc)) in sreqs.iter().enumerate() {
            e.submit(i, p.clone(), gc.clone()).unwrap();
        }
        let mut done =
            e.run_spec(&exec, &entry, target, Some(drafter)).unwrap();
        done.sort_unstable_by_key(|(i, _)| *i);
        for ((_, g), p) in done.iter().zip(&plain_out) {
            assert_eq!(g.tokens, p.tokens,
                       "speculation changed tokens (k={k})");
        }
        let sc = e.spec_counters();
        let r = bench(&format!("spec decode k={k} (2-bit drafter)"),
                      || {
            black_box(
                generate_batch_spec(&exec, &entry, target, drafter,
                                    &sreqs, b)
                    .unwrap());
        });
        println!(
            "  -> k={k}: {:.2} tokens/target-pass, accept rate \
             {:.0}%, {:.0} tok/s e2e ({:.2}x vs plain)",
            sc.tokens_per_verify(),
            100.0 * sc.accept_rate(),
            tok_s(r.median_ns),
            plain.median_ns / r.median_ns
        );
    }

    // Acceptance ceiling: drafter == target accepts everything, so
    // tokens/target-pass pins at k + 1 (no e2e win — the "drafter"
    // costs as much as the target — but it calibrates the rows above).
    let k = 4usize;
    let sreqs = reqs(Some(k));
    let mut e: BatchEngine<usize> = BatchEngine::new(&cfg, b);
    for (i, (p, gc)) in sreqs.iter().enumerate() {
        e.submit(i, p.clone(), gc.clone()).unwrap();
    }
    e.run_spec(&exec, &entry, target, Some(target)).unwrap();
    let sc = e.spec_counters();
    println!(
        "  -> ceiling (drafter == target, k={k}): {:.2} \
         tokens/target-pass at {:.0}% acceptance",
        sc.tokens_per_verify(),
        100.0 * sc.accept_rate()
    );
}

/// Mixed-precision KV pages: resident KV bytes and per-token decode
/// cost at one matched batch size across f32 / int8 / int4 / the
/// NSDS-allocated mixed plan (same model, same requests — only the
/// cache storage width changes), plus a speculative row whose drafter
/// pool opts into 4-bit KV while the target keeps the NSDS plan. The
/// decode rows pin that fused dequant is a bytes win, not a decode
/// tax; the spec row pins that drafter KV precision never touches the
/// committed tokens.
fn kv_quant_section() {
    let cfg = ModelConfig::llama_s_synth();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(11);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::new();
    let model = ModelRef::Dense(&fp);
    let b = 8usize;
    let held = 24usize;
    const STEPS: usize = 8;

    // The paper's machinery end to end: NSDS dual-sensitivity layer
    // scores -> {4, 8, 16} KV widths under a 6-bit/element average.
    let scores = nsds_layer_scores(&cfg, &fp, &NsdsOptions::default());
    let plan = allocate_kv_bits(&scores, 6.0);
    println!("== mixed-precision KV: resident bytes + decode cost \
              (B={b}, {held} tokens held) ==");
    println!("  -> nsds kv plan (b̄=6): {plan:?}");

    let plans: [(&str, Vec<u8>); 4] = [
        ("f32", vec![16u8; cfg.n_layers]),
        ("kv8", vec![8u8; cfg.n_layers]),
        ("kv4", vec![4u8; cfg.n_layers]),
        ("nsds-mixed", plan.clone()),
    ];
    let mut f32_bytes = 0usize;
    for (label, bits) in &plans {
        let mut pool = KvCachePool::for_model_with_bits(&cfg, b, bits);
        let slots: Vec<usize> =
            (0..b).map(|_| pool.admit(cfg.seq).unwrap()).collect();
        for i in 0..held {
            let active: Vec<(usize, i32)> = slots
                .iter()
                .map(|&s| (s, ((i + s) % cfg.vocab) as i32))
                .collect();
            model
                .decode_batch(&exec, &entry, &mut pool, &active)
                .unwrap();
        }
        if *label == "f32" {
            f32_bytes = pool.bytes();
        }
        println!(
            "  -> {label}: {} KiB resident ({:.2}x smaller than f32)",
            pool.bytes() / 1024,
            f32_bytes as f64 / pool.bytes() as f64
        );
        let mut p = pool;
        let r = bench(
            &format!("decode_batch {STEPS} steps kv={label} B={b}"),
            || {
                for j in 0..STEPS {
                    let active: Vec<(usize, i32)> = slots
                        .iter()
                        .map(|&s| (s, ((j + s) % cfg.vocab) as i32))
                        .collect();
                    black_box(
                        model
                            .decode_batch(&exec, &entry, &mut p,
                                          &active)
                            .unwrap(),
                    );
                }
            },
        );
        println!("  -> kv={label}: {:.0} ns/token",
                 r.median_ns / (STEPS * b) as f64);
    }

    // Spec row: target pool on the NSDS plan, drafter pool opted into
    // all-4-bit KV (draft tokens are disposable guesses verified
    // exactly, so drafter KV precision trades only accept rate).
    let workers = default_workers();
    let d2 = QuantizedModel::quantize(&cfg, &fp,
                                      &vec![2u8; cfg.n_layers],
                                      DEFAULT_GROUP, Backend::Rtn,
                                      None, workers);
    let drafter = ModelRef::Packed(&d2);
    let sb = 4usize;
    let plen = 16usize;
    let max_new = if harness::quick() { 16 } else { 32 };
    let mk_reqs = |k: Option<usize>| -> Vec<(Vec<i32>, GenConfig)> {
        (0..sb)
            .map(|i| {
                let prompt: Vec<i32> = (0..plen)
                    .map(|j| ((3 * i + 7 * j) % cfg.vocab) as i32)
                    .collect();
                let gc = GenConfig {
                    max_new,
                    spec: k.map(|k| SpecDecode { k }),
                    ..GenConfig::default()
                };
                (prompt, gc)
            })
            .collect()
    };
    let entry_plan =
        ModelEntry::synthetic(cfg.clone()).with_kv_bits(plan.clone());
    let plain = generate_batch(&exec, &entry_plan, model,
                               &mk_reqs(None), sb)
        .unwrap();
    let run_kv_spec = || -> BatchEngine<usize> {
        let mut e: BatchEngine<usize> = BatchEngine::with_kv_bits(
            &cfg, sb, Some(plan.clone()));
        e.set_drafter_kv_bits(Some(vec![4u8; cfg.n_layers]));
        for (i, (p, gc)) in mk_reqs(Some(4)).iter().enumerate() {
            e.submit(i, p.clone(), gc.clone()).unwrap();
        }
        e
    };
    let mut e = run_kv_spec();
    let mut done =
        e.run_spec(&exec, &entry_plan, model, Some(drafter)).unwrap();
    done.sort_unstable_by_key(|(i, _)| *i);
    for ((_, g), p) in done.iter().zip(&plain) {
        assert_eq!(g.tokens, p.tokens,
                   "4-bit-KV drafter changed committed tokens");
    }
    let sc = e.spec_counters();
    let dbytes =
        e.drafter_pool().map(|p| p.bytes()).unwrap_or(0);
    let r = bench("spec decode k=4 (nsds target KV, 4-bit drafter \
                   KV)", || {
        let mut e = run_kv_spec();
        black_box(
            e.run_spec(&exec, &entry_plan, model, Some(drafter))
                .unwrap());
    });
    let tok_s = (sb * max_new) as f64 / (r.median_ns / 1e9);
    println!(
        "  -> spec k=4, 4-bit drafter KV: {:.2} tokens/target-pass, \
         accept {:.0}%, {:.0} tok/s, drafter pool {} KiB — tokens \
         bit-identical to plain decode",
        sc.tokens_per_verify(),
        100.0 * sc.accept_rate(),
        tok_s,
        dbytes / 1024
    );
}

/// Streaming front-end cost: per-token latency of generation with a
/// real channel sink attached (one send per committed token — what
/// `Client::generate_streaming` / the HTTP SSE path pay) vs the no-op
/// buffered tags, plus cancel-reclaim latency — how long a dead
/// client's disconnect holds its KV slot before the scheduler retires
/// it (pinned by test to one step; here we put a wall-clock number on
/// that step).
fn stream_section() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{mpsc, Arc};

    /// What the server attaches per request: an unbounded channel send
    /// per event plus the open-flag probe the scheduler polls.
    struct ChannelSink {
        tx: mpsc::Sender<GenEvent>,
        open: Arc<AtomicBool>,
    }

    impl GenSink for ChannelSink {
        fn emit(&self, ev: GenEvent) -> bool {
            self.open.load(Ordering::Acquire)
                && self.tx.send(ev).is_ok()
        }

        fn is_connected(&self) -> bool {
            self.open.load(Ordering::Acquire)
        }
    }

    let cfg = ModelConfig::llama_s_synth();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(12);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let workers = default_workers();
    let t4 = QuantizedModel::quantize(&cfg, &fp,
                                      &vec![4u8; cfg.n_layers],
                                      DEFAULT_GROUP, Backend::Rtn,
                                      None, workers);
    let exec = NativeEngine::new();
    let model = ModelRef::Packed(&t4);

    let b = 4usize;
    let plen = 16usize;
    let max_new = if harness::quick() { 16 } else { 48 };
    let prompt = |i: usize| -> Vec<i32> {
        (0..plen)
            .map(|j| ((3 * i + 7 * j) % cfg.vocab) as i32)
            .collect()
    };
    let gc = GenConfig { max_new, ..GenConfig::default() };
    let total_tokens = (b * max_new) as f64;

    // Fresh engine per iteration on both sides so the comparison
    // isolates the sink, not engine setup.
    println!("== streaming: per-token emit cost + cancel-reclaim \
              (B={b}, {max_new} tokens/request, 4-bit target) ==");
    let buffered = bench("buffered generate (no-op tags)", || {
        let mut e: BatchEngine<usize> = BatchEngine::new(&cfg, b);
        for i in 0..b {
            assert!(e.submit(i, prompt(i), gc.clone()).is_ok());
        }
        black_box(e.run(&exec, &entry, model).unwrap());
    });
    let streamed = bench("streamed generate (channel sinks)", || {
        let mut e: BatchEngine<ChannelSink> = BatchEngine::new(&cfg, b);
        let mut rxs = Vec::with_capacity(b);
        for i in 0..b {
            let (tx, rx) = mpsc::channel();
            rxs.push(rx);
            let sink = ChannelSink {
                tx,
                open: Arc::new(AtomicBool::new(true)),
            };
            assert!(e.submit(sink, prompt(i), gc.clone()).is_ok());
        }
        black_box(e.run(&exec, &entry, model).unwrap());
        // Drain what a client would read: Token xN then Done.
        for rx in rxs {
            black_box(rx.try_iter().count());
        }
    });
    println!(
        "  -> per token: buffered {:.0} ns, streamed {:.0} ns \
         (emit overhead {:+.0} ns/token, {:+.1}%)",
        buffered.median_ns / total_tokens,
        streamed.median_ns / total_tokens,
        (streamed.median_ns - buffered.median_ns) / total_tokens,
        100.0 * (streamed.median_ns - buffered.median_ns)
            / buffered.median_ns
    );

    // Cancel-reclaim: submit B streams, decode one step, hang up on
    // request 0, and count scheduler steps + wall time until the
    // engine retires it. State-mutating, so measured one-shot rather
    // than through the harness loop.
    let mut e: BatchEngine<ChannelSink> = BatchEngine::new(&cfg, b);
    let mut flags = Vec::with_capacity(b);
    let mut rxs = Vec::with_capacity(b);
    for i in 0..b {
        let (tx, rx) = mpsc::channel();
        rxs.push(rx);
        let open = Arc::new(AtomicBool::new(true));
        flags.push(open.clone());
        let sink = ChannelSink { tx, open };
        assert!(e.submit(sink, prompt(i), gc.clone()).is_ok());
    }
    e.step(&exec, &entry, model).unwrap();
    let pages_before = e.pool().pages_in_use();
    flags[0].store(false, Ordering::Release);
    drop(rxs.remove(0));
    let t0 = std::time::Instant::now();
    let mut steps = 0usize;
    while e.cancelled_total() == 0 {
        e.step(&exec, &entry, model).unwrap();
        steps += 1;
        assert!(steps <= 4, "disconnect never reclaimed the slot");
    }
    let reclaim_ns = t0.elapsed().as_nanos() as f64;
    println!(
        "  -> cancel reclaim: {steps} step(s), {:.0} us wall, pages \
         {pages_before} -> {} (in-flight {b} -> {})",
        reclaim_ns / 1e3,
        e.pool().pages_in_use(),
        e.in_flight()
    );
}

fn pipeline_section() -> anyhow::Result<()> {
    use nsds::baselines::Method;
    use nsds::coordinator::Pipeline;
    use nsds::eval::EvalOptions;
    use nsds::sensitivity::Ablation;

    let p = Pipeline::new()?;
    let corpora = nsds::eval::ppl::load_corpora(&p.man)?;
    let b = p.man.eval_batch;

    println!("== forward-batch latency (batch={b}, executor={}) ==",
             p.exec().platform());
    for model in ["llama-s", "qwen-s", "llama-m"] {
        let entry = p.entry(model)?;
        let w = p.weights(model)?;
        let s = entry.config.seq;
        let chunk = &corpora.wiki_like[..b * s];
        // warm-up (compiles on PJRT) outside the timing loop
        p.exec().forward(entry, chunk, b, &w)?;
        bench(&format!("fwd {model} [{}x{}]", b, s), || {
            black_box(p.exec().forward(entry, chunk, b, &w).unwrap());
        });
    }

    #[cfg(feature = "xla")]
    pjrt_kernel_section(&p)?;

    println!("== end-to-end table-1 cell (llama-s, NSDS, b̄=3, HQQ) ==");
    let t0 = std::time::Instant::now();
    let method = Method::Nsds(Ablation::Full);
    let scores = p.scores(method, "llama-s")?;
    let t_score = t0.elapsed().as_secs_f64();
    let bits = nsds::allocate::allocate_bits(&scores, 3.0);
    let qw = p.quantize("llama-s", &bits, Backend::Hqq)?;
    let t_quant = t0.elapsed().as_secs_f64() - t_score;
    let r = p.eval("llama-s", &qw, &EvalOptions::default())?;
    let t_eval = t0.elapsed().as_secs_f64() - t_score - t_quant;
    println!(
        "e2e breakdown: score {t_score:.2}s  quantize {t_quant:.2}s  \
         eval {t_eval:.2}s  (avg acc {:.2}%)",
        r.avg_acc()
    );
    Ok(())
}

/// The standalone Pallas dequant kernels, executed through PJRT.
#[cfg(feature = "xla")]
fn pjrt_kernel_section(
    p: &nsds::coordinator::Pipeline) -> anyhow::Result<()> {
    use nsds::quant::pack;
    use nsds::runtime::{Engine, Input};

    let dir = Manifest::default_dir();
    let engine = Engine::cpu(&dir)?;
    let mut rng = Rng::new(5);
    println!("== fused dequant-matmul Pallas kernels (PJRT) ==");
    for k in &p.man.kernels {
        if !k.file.starts_with("dequant") {
            continue;
        }
        let w = Tensor::randn(vec![k.k, k.n], &mut rng);
        let x = Tensor::randn(vec![k.m, k.k], &mut rng);
        let q = rtn::quantize(&w, QuantSpec::new(k.bits, k.group));
        let packed = pack::pack(&q.codes, k.k, k.n, k.bits);
        let scale = Tensor::new(q.scale.clone(), vec![k.k / k.group, k.n]);
        let zero = Tensor::new(q.zero.clone(), vec![k.k / k.group, k.n]);
        engine.load(&k.file)?;
        bench(&format!("kernel {} [{}x{}x{}]", k.file, k.m, k.k, k.n),
              || {
            black_box(
                engine
                    .execute(&k.file, &[
                        Input::F32(&x),
                        Input::U8(&packed,
                                  vec![k.k * k.bits as usize / 8, k.n]),
                        Input::F32(&scale),
                        Input::F32(&zero),
                    ])
                    .unwrap(),
            );
        });
    }
    Ok(())
}

/// Write the run's entries as the versioned bench document, then
/// re-read and validate what landed on disk — the same check CI's
/// bench-smoke job relies on (exit nonzero ⇔ the artifact is unusable).
fn write_json_report(
    entries: &[nsds::telemetry::BenchEntry]) -> anyhow::Result<()> {
    let doc = nsds::telemetry::bench_report("bench_runtime", entries);
    let path = "BENCH_runtime.json";
    std::fs::write(path, format!("{doc}\n"))?;
    let text = std::fs::read_to_string(path)?;
    let parsed = nsds::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{path} re-parse failed: {e}"))?;
    nsds::telemetry::validate_bench_report(&parsed)
        .map_err(|e| anyhow::anyhow!("{path} schema-invalid: {e}"))?;
    println!("wrote {path}: {} entries, schema v{}", entries.len(),
             nsds::telemetry::SCHEMA_VERSION);
    Ok(())
}

/// Sections the baseline diff gates on. The native/pipeline sections
/// churn with hardware and artifact availability; decode + prefill are
/// the serving-latency headline this repo's kernels exist for, and
/// their entry names are stable across runs.
const GATED_SECTIONS: [&str; 2] = ["decode", "prefill"];

/// Regression threshold: a gated entry may not take more than 2x its
/// baseline median. Generous on purpose — CI smoke boxes are noisy and
/// `--quick` numbers are plumbing checks, so this only trips on the
/// kind of wreckage (accidental O(prefix) decode, dead-path fallback)
/// that no amount of scheduler jitter produces.
const REGRESSION_FACTOR: f64 = 2.0;

/// Diff this run's gated sections against a committed baseline bench
/// document. Entries are matched by (section, name); entries missing
/// on either side are reported but don't fail (bench sets evolve).
/// Returns Err (⇒ nonzero exit) iff some matched entry regressed by
/// more than `REGRESSION_FACTOR`.
fn diff_against_baseline(
    path: &str,
    fresh: &[nsds::telemetry::BenchEntry]) -> anyhow::Result<()> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => {
            println!(
                "bench_runtime: no baseline at {path}; skipping the \
                 regression diff (commit a `--quick --json` run's \
                 BENCH_runtime.json as {path} to arm it)");
            return Ok(());
        }
    };
    let parsed = nsds::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{path} parse failed: {e}"))?;
    let base = nsds::telemetry::bench_entries_from_json(&parsed)
        .map_err(|e| anyhow::anyhow!("{path} schema-invalid: {e}"))?;

    println!("== baseline diff vs {path} (sections {:?}, fail > \
              {REGRESSION_FACTOR:.1}x) ==", GATED_SECTIONS);
    let mut regressed = Vec::new();
    let mut matched = 0usize;
    for e in fresh.iter().filter(|e| {
        GATED_SECTIONS.contains(&e.section.as_str())
    }) {
        let Some(b) = base.iter().find(|b| {
            b.section == e.section && b.name == e.name
        }) else {
            println!("  -> [{}] {}: new entry, no baseline (skipped)",
                     e.section, e.name);
            continue;
        };
        matched += 1;
        let ratio = e.median_ns / b.median_ns;
        let flag = if ratio > REGRESSION_FACTOR { "REGRESSED" }
                   else { "ok" };
        println!("  -> [{}] {}: {:.0} ns vs {:.0} ns ({ratio:.2}x) \
                  {flag}", e.section, e.name, e.median_ns, b.median_ns);
        if ratio > REGRESSION_FACTOR {
            regressed.push(format!("[{}] {} {ratio:.2}x",
                                   e.section, e.name));
        }
    }
    if matched == 0 {
        println!("  -> no gated entries matched the baseline \
                  (name drift?); nothing gated");
    }
    if regressed.is_empty() {
        Ok(())
    } else {
        anyhow::bail!("baseline regression (> {REGRESSION_FACTOR:.1}x \
                       median) in {} entr{}: {}",
                      regressed.len(),
                      if regressed.len() == 1 { "y" } else { "ies" },
                      regressed.join(", "))
    }
}

fn main() -> anyhow::Result<()> {
    // `cargo bench` also passes harness flags like `--bench`; take
    // what we know, ignore the rest.
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json = args.iter().any(|a| a == "--json");
    let baseline = args
        .iter()
        .position(|a| a == "--baseline")
        .map(|i| {
            args.get(i + 1)
                .filter(|v| !v.starts_with("--"))
                .cloned()
                .ok_or_else(|| {
                    anyhow::anyhow!("--baseline needs a path argument")
                })
        })
        .transpose()?;
    harness::set_quick(args.iter().any(|a| a == "--quick"));

    harness::set_section("native");
    native_section();
    harness::set_section("decode");
    decode_section();
    harness::set_section("batch_decode");
    batch_decode_section();
    harness::set_section("prefill");
    prefill_section();
    harness::set_section("paged_kv");
    paged_kv_section();
    harness::set_section("spec_decode");
    spec_decode_section();
    harness::set_section("kv_quant");
    kv_quant_section();
    harness::set_section("stream");
    stream_section();
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        harness::set_section("pipeline");
        pipeline_section()?;
    } else {
        println!("bench_runtime: no artifacts (run `make artifacts`); \
                  skipping pipeline benches");
    }
    let entries = harness::take_results();
    if json {
        write_json_report(&entries)?;
    }
    if let Some(path) = baseline {
        diff_against_baseline(&path, &entries)?;
    }
    Ok(())
}
