//! Runtime benches (need artifacts; exit 0 with a notice otherwise):
//! forward-batch latency per model, the fused dequant-matmul Pallas
//! kernels, probe/grad executables, and an end-to-end table-1-cell run
//! (score → allocate → quantize → eval) with a timing breakdown.
//! These regenerate the latency/throughput side of every paper exhibit.

#[path = "harness.rs"]
mod harness;

use harness::{bench, black_box};
use nsds::baselines::Method;
use nsds::coordinator::Pipeline;
use nsds::eval::EvalOptions;
use nsds::quant::Backend;
use nsds::runtime::{run_forward, Input, Manifest};
use nsds::sensitivity::Ablation;
use nsds::tensor::Tensor;
use nsds::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("bench_runtime: no artifacts (run `make artifacts`); \
                  skipping");
        return Ok(());
    }
    let p = Pipeline::new()?;
    let corpora = nsds::eval::ppl::load_corpora(&p.man)?;
    let b = p.man.eval_batch;

    println!("== forward-batch latency (batch={b}) ==");
    for model in ["llama-s", "qwen-s", "llama-m"] {
        let entry = p.entry(model)?;
        let w = p.weights(model)?;
        let s = entry.config.seq;
        let chunk = &corpora.wiki_like[..b * s];
        // warm-up compiles outside the timing loop
        run_forward(&p.engine, entry, chunk, b, &w)?;
        bench(&format!("fwd {model} [{}x{}]", b, s), || {
            black_box(run_forward(&p.engine, entry, chunk, b, &w)
                .unwrap());
        });
    }

    println!("== fused dequant-matmul Pallas kernels ==");
    let mut rng = Rng::new(5);
    for k in &p.man.kernels {
        if !k.file.starts_with("dequant") {
            continue;
        }
        let w = Tensor::randn(vec![k.k, k.n], &mut rng);
        let x = Tensor::randn(vec![k.m, k.k], &mut rng);
        let q = nsds::quant::rtn::quantize(
            &w, nsds::quant::QuantSpec::new(k.bits, k.group));
        let packed = nsds::quant::pack::pack(&q.codes, k.k, k.n, k.bits);
        let scale = Tensor::new(q.scale.clone(), vec![k.k / k.group, k.n]);
        let zero = Tensor::new(q.zero.clone(), vec![k.k / k.group, k.n]);
        p.engine.load(&k.file)?;
        bench(&format!("kernel {} [{}x{}x{}]", k.file, k.m, k.k, k.n),
              || {
            black_box(
                p.engine
                    .execute(&k.file, &[
                        Input::F32(&x),
                        Input::U8(&packed,
                                  vec![k.k * k.bits as usize / 8, k.n]),
                        Input::F32(&scale),
                        Input::F32(&zero),
                    ])
                    .unwrap(),
            );
        });
    }

    println!("== end-to-end table-1 cell (llama-s, NSDS, b̄=3, HQQ) ==");
    let t0 = std::time::Instant::now();
    let method = Method::Nsds(Ablation::Full);
    let scores = p.scores(method, "llama-s")?;
    let t_score = t0.elapsed().as_secs_f64();
    let bits = nsds::allocate::allocate_bits(&scores, 3.0);
    let qw = p.quantize("llama-s", &bits, Backend::Hqq)?;
    let t_quant = t0.elapsed().as_secs_f64() - t_score;
    let r = p.eval("llama-s", &qw, &EvalOptions::default())?;
    let t_eval = t0.elapsed().as_secs_f64() - t_score - t_quant;
    println!(
        "e2e breakdown: score {t_score:.2}s  quantize {t_quant:.2}s  \
         eval {t_eval:.2}s  (avg acc {:.2}%)",
        r.avg_acc()
    );
    Ok(())
}
