//! Minimal criterion-style bench harness (criterion is unreachable
//! offline — DESIGN.md "Environment deviations").
//!
//! Each bench target sets `harness = false` in Cargo.toml and calls
//! `bench(name, || work)`: adaptive iteration count targeting ~0.5 s
//! per measurement (~0.02 s in `--quick` mode, the CI smoke setting),
//! reporting median / mean / p95 per-iteration time. Results append to
//! `bench_results.tsv` (gitignored) so the perf pass can diff
//! before/after, and accumulate in memory tagged with the current
//! `set_section` label — `take_results` hands them to the versioned
//! `telemetry::bench_report` JSON export (`BENCH_runtime.json`).
#![allow(dead_code)]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use nsds::telemetry::BenchEntry;

#[derive(Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

static QUICK: AtomicBool = AtomicBool::new(false);
static SECTION: Mutex<String> = Mutex::new(String::new());
static RESULTS: Mutex<Vec<BenchEntry>> = Mutex::new(Vec::new());

/// Quick mode: ~25x shorter measurement target. CI's bench-smoke job
/// uses this — it checks the harness + export plumbing, not the
/// numbers' stability.
pub fn set_quick(on: bool) {
    QUICK.store(on, Ordering::Relaxed);
}

pub fn quick() -> bool {
    QUICK.load(Ordering::Relaxed)
}

/// Label the bench section subsequent `bench` calls belong to (becomes
/// the section name in `BENCH_runtime.json`).
pub fn set_section(name: &str) {
    *SECTION.lock().unwrap() = name.to_string();
}

/// Drain every result recorded so far, in run order.
pub fn take_results() -> Vec<BenchEntry> {
    std::mem::take(&mut *RESULTS.lock().unwrap())
}

/// Run `f` adaptively and report stats. Returns per-iter median ns.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // Warm-up + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let target = if quick() { 0.02f64 } else { 0.5f64 };
    let iters = ((target / once) as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e9);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p95_idx =
        ((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1);
    let p95 = samples[p95_idx];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median_ns: median,
        mean_ns: mean,
        p95_ns: p95,
    };
    println!(
        "{:<44} {:>10} iters   median {:>12}   mean {:>12}   p95 {:>12}",
        r.name, r.iters, fmt_ns(median), fmt_ns(mean), fmt_ns(p95)
    );
    append_tsv(&r);
    RESULTS.lock().unwrap().push(BenchEntry {
        section: SECTION.lock().unwrap().clone(),
        name: r.name.clone(),
        iters: r.iters as u64,
        median_ns: r.median_ns,
        mean_ns: r.mean_ns,
        p95_ns: r.p95_ns,
    });
    r
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn append_tsv(r: &BenchResult) {
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("bench_results.tsv")
    {
        let _ = writeln!(
            f,
            "{}\t{}\t{:.1}\t{:.1}\t{:.1}",
            r.name, r.iters, r.median_ns, r.mean_ns, r.p95_ns
        );
    }
}

/// Prevent the optimizer from deleting the benched computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
