//! Core-numerics benches: SVD, matmul, stats, quantization backends —
//! the L3 hot paths behind sensitivity scoring (EXPERIMENTS.md §Perf).

#[path = "harness.rs"]
mod harness;

use harness::{bench, black_box};
use nsds::quant::{Backend, QuantSpec};
use nsds::tensor::matmul::{gram, matmul};
use nsds::tensor::stats::excess_kurtosis;
use nsds::tensor::svd::svd;
use nsds::tensor::Tensor;
use nsds::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    println!("== core numerics ==");

    for &n in &[64usize, 96, 256] {
        let a = Tensor::randn(vec![n, n], &mut rng);
        bench(&format!("svd {n}x{n}"), || {
            black_box(svd(&a));
        });
    }
    let wide = Tensor::randn(vec![64, 256], &mut rng);
    bench("svd 64x256 (unembed)", || {
        black_box(svd(&wide));
    });

    for &(m, k, n) in &[(64usize, 64usize, 64usize), (512, 96, 288)] {
        let a = Tensor::randn(vec![m, k], &mut rng);
        let b = Tensor::randn(vec![k, n], &mut rng);
        bench(&format!("matmul {m}x{k}x{n}"), || {
            black_box(matmul(&a, &b));
        });
    }
    let x = Tensor::randn(vec![2048, 96], &mut rng);
    bench("gram 2048x96 (hessian)", || {
        black_box(gram(&x));
    });

    let big = Tensor::randn(vec![288, 96], &mut rng);
    bench("kurtosis 288x96", || {
        black_box(excess_kurtosis(big.data()));
    });

    println!("== quantization backends (192x64 matrix, g=32) ==");
    let w = Tensor::randn(vec![192, 64], &mut rng);
    for (label, backend) in [("rtn", Backend::Rtn), ("hqq", Backend::Hqq),
                             ("gptq-idH", Backend::Gptq)] {
        for bits in [2u8, 4] {
            bench(&format!("{label} {bits}-bit 192x64"), || {
                black_box(nsds::quant::quantize_matrix(
                    &w, QuantSpec::new(bits, 32), backend, None));
            });
        }
    }
    let xact = Tensor::randn(vec![512, 192], &mut rng);
    let h = nsds::quant::gptq::hessian_from_inputs(&xact);
    bench("gptq real-H 2-bit 192x64", || {
        black_box(nsds::quant::gptq::quantize(
            &w, QuantSpec::new(2, 32), Some(&h)));
    });
}
