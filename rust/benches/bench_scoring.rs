//! Sensitivity-scoring benches: full NSDS (per table-1 model shape) and
//! every calibration-free baseline — the offline cost a user pays before
//! deployment. One bench per paper-table model shape.

#[path = "harness.rs"]
mod harness;

use harness::{bench, black_box};
use nsds::model::{ModelConfig, Weights};
use nsds::sensitivity::{nsds_layer_scores, NsdsOptions};
use nsds::util::rng::Rng;

fn shape(name: &str, d: usize, h: usize, kv: usize, dh: usize, f: usize,
         l: usize) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        vocab: 256,
        d_model: d,
        n_heads: h,
        n_kv: kv,
        d_head: dh,
        d_ffn: f,
        n_layers: l,
        seq: 64,
    }
}

fn main() {
    let mut rng = Rng::new(11);
    let configs = [
        shape("llama-s", 64, 4, 2, 16, 192, 8),
        shape("qwen-s", 64, 8, 4, 8, 256, 8),
        shape("llama-m", 96, 6, 6, 16, 256, 12),
    ];
    println!("== NSDS scoring (full metric, 1 worker) ==");
    for cfg in &configs {
        let w = Weights::synth(cfg, &mut rng, &[], &[]);
        let opts = NsdsOptions { workers: 1, ..Default::default() };
        bench(&format!("nsds scores {}", cfg.name), || {
            black_box(nsds_layer_scores(cfg, &w, &opts));
        });
    }

    println!("== calibration-free baselines (llama-s shape) ==");
    let cfg = &configs[0];
    let w = Weights::synth(cfg, &mut rng, &[], &[]);
    bench("mse scores", || {
        black_box(nsds::baselines::free::mse(cfg, &w, 1));
    });
    bench("ewq scores", || {
        black_box(nsds::baselines::free::ewq(cfg, &w, 1));
    });
    bench("zd scores", || {
        black_box(nsds::baselines::free::zd(cfg, &w, 1));
    });
    bench("kurtboost scores", || {
        black_box(nsds::baselines::free::kurtboost_scores(cfg, &w, 1));
    });
}
