//! Router/batcher demo: concurrent clients + dynamic batching + a
//! zero-downtime quantized-weight swap mid-stream.
//!
//!   cargo run --release --example router_demo [model] [n_clients] [reqs]
//!
//! Four client threads stream scoring requests into the bounded queue;
//! the main thread runs the serve loop over the pipeline's executor
//! (engine handles stay on one thread).
//! Halfway through, a client deploys the NSDS@3-bit variant via a queued
//! weight-swap — ordered with in-flight requests, no recompilation.

use std::sync::Arc;

use nsds::baselines::Method;
use nsds::coordinator::server::{serve, Client, ServedWeights,
                                ServerQueue};
use nsds::coordinator::Pipeline;
use nsds::infer::NativeEngine;
use nsds::quant::Backend;
use nsds::sensitivity::Ablation;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("llama-s");
    let n_clients: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let per_client: usize =
        args.get(3).and_then(|s| s.parse().ok()).unwrap_or(24);

    let p = Pipeline::new()?;
    let entry = p.entry(model)?.clone();
    let seq = entry.config.seq;
    let batch = p.man.eval_batch;
    let fp = p.weights(model)?;
    let bits = p.allocate(Method::Nsds(Ablation::Full), model, 3.0)?;
    let q3 = p.quantize(model, &bits, Backend::Hqq)?;
    let corpora = nsds::eval::ppl::load_corpora(&p.man)?;
    let train = Arc::new(corpora.train);

    let queue = ServerQueue::new(batch * 4);
    let swap_at = n_clients * per_client / 2;
    let counter = Arc::new(std::sync::atomic::AtomicUsize::new(0));

    // Client threads.
    let mut handles = Vec::new();
    for cid in 0..n_clients {
        let client = Client::new(queue.clone(), seq);
        let train = train.clone();
        let counter = counter.clone();
        let q3 = q3.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<f64> {
            let mut total_nll = 0.0;
            let mut total_n = 0usize;
            for r in 0..per_client {
                let off = ((cid * 7919 + r * 613) * seq)
                    % (train.len() - seq);
                let toks = train[off..off + seq].to_vec();
                let k = counter
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if k == swap_at {
                    println!("[client {cid}] deploying NSDS@3-bit \
                              (request #{k}) — no recompile");
                    client.swap_weights(q3.clone());
                }
                let (nll, n) = client.nll(toks)?;
                total_nll += nll;
                total_n += n;
            }
            Ok((total_nll / total_n as f64).exp())
        }));
    }

    // Stopper: once all clients finish, a stop message ends the loop.
    {
        let queue = queue.clone();
        let done = handles.len();
        let _ = done;
        let stop_client = Client::new(queue, seq);
        let counter = counter.clone();
        let total = n_clients * per_client;
        std::thread::spawn(move || {
            loop {
                if counter.load(std::sync::atomic::Ordering::Relaxed)
                    >= total
                {
                    // Give the last replies a moment, then stop.
                    std::thread::sleep(
                        std::time::Duration::from_millis(300));
                    stop_client.stop();
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        });
    }

    // Engine thread = main thread. `serve` needs a `Sync` executor (it
    // fans concurrent generations across pool workers), so the demo
    // serves on the native engine — the default executor offline anyway.
    let engine = NativeEngine::new();
    let t0 = std::time::Instant::now();
    serve(&engine, &entry, batch, ServedWeights::Dense(fp), &queue)?;
    let dt = t0.elapsed().as_secs_f64();

    let (served, batches, padded) = queue.stats();
    println!("served {served} requests in {batches} batches \
              ({padded} padded rows) over {dt:.2}s \
              -> {:.1} req/s, avg batch fill {:.1}%",
             served as f64 / dt,
             100.0 * served as f64 / (batches as f64 * batch as f64));
    // Same data the JSON export serializes, rendered for eyes.
    print!("{}",
           nsds::telemetry::render_summary(&queue.metrics().snapshot()));
    for (cid, h) in handles.into_iter().enumerate() {
        let ppl = h.join().unwrap()?;
        println!("client {cid}: stream ppl {ppl:.3}");
    }
    Ok(())
}
