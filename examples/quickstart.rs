//! Quickstart: the five-line NSDS workflow.
//!
//!   cargo run --release --example quickstart
//!
//! Loads a trained model from the artifacts, scores every layer with the
//! data-free NSDS metric, allocates bits for a 3-bit average budget,
//! quantizes with HQQ, and evaluates perplexity + reasoning accuracy
//! through the PJRT runtime.

use nsds::baselines::Method;
use nsds::coordinator::Pipeline;
use nsds::eval::EvalOptions;
use nsds::quant::Backend;
use nsds::sensitivity::Ablation;

fn main() -> anyhow::Result<()> {
    let pipeline = Pipeline::new()?; // loads artifacts/manifest.json
    let model = "llama-s";

    // 1. Data-free layer sensitivity scores (no calibration pass!).
    let scores = pipeline.scores(Method::Nsds(Ablation::Full), model)?;
    println!("NSDS layer scores: {scores:.3?}");

    // 2. Closed-form bit allocation at an average budget of 3 bits.
    let bits = pipeline.allocate(Method::Nsds(Ablation::Full), model, 3.0)?;
    println!("allocation (4-bit = sensitive): {bits:?}");

    // 3. Quantize with the calibration-free HQQ backend.
    let quantized = pipeline.quantize(model, &bits, Backend::Hqq)?;

    // 4. Evaluate through the AOT-compiled PJRT executable.
    let fp = pipeline.eval_fp(model, &EvalOptions::default())?;
    let q = pipeline.eval(model, &quantized, &EvalOptions::default())?;
    println!("FP32 : avg acc {:6.2}%  avg ppl {:7.3}", fp.avg_acc(),
             fp.avg_ppl());
    println!("3-bit: avg acc {:6.2}%  avg ppl {:7.3}", q.avg_acc(),
             q.avg_ppl());
    Ok(())
}
