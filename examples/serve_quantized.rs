//! Serving scenario: batched NLL scoring AND KV-cached autoregressive
//! generation over the weight-swappable executor — the deployment shape a
//! quantized LLM service uses.
//!
//!   cargo run --release --example serve_quantized [model] [n_requests]
//!
//! With `artifacts/` present (after `make artifacts`) it serves the
//! trained model zoo through the coordinator pipeline; without artifacts
//! it falls back to a fully synthetic llama-s-shaped deployment on the
//! native engine, so the example runs on a clean offline checkout.
//! Either way it compares deployed variants (FP32 vs packed quantized)
//! on per-request forward latency and on generation: tokens/sec,
//! prefill/decode split, and greedy-output agreement between the FP32
//! and packed variants.

use std::time::Instant;

use nsds::coordinator::http::{parse_sse, HttpServer};
use nsds::coordinator::server::{serve, Client, ServedWeights,
                                ServerQueue};
use nsds::infer::{generate, Executor, GenConfig, GenEvent, ModelRef,
                  NativeEngine, QuantizedModel, Sampling};
use nsds::model::{ModelConfig, Weights};
use nsds::quant::Backend;
use nsds::runtime::{run_forward, ModelEntry};
use nsds::telemetry::{render_summary, snapshot_from_json,
                      MetricsRegistry};
use nsds::util::json::Json;
use nsds::util::rng::Rng;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p) as usize;
    sorted[idx]
}

/// Generation showcase shared by both modes: greedy + top-k from every
/// variant, with per-request stats and FP-vs-packed greedy agreement,
/// plus the telemetry snapshot summary the runs recorded.
fn generation_demo(exec: &dyn Executor, entry: &ModelEntry,
                   fp: ModelRef, packed: ModelRef,
                   corpus: &[i32]) -> anyhow::Result<()> {
    let reg = MetricsRegistry::new();
    let h_ttft = reg.histogram("demo.gen.ttft_ns");
    let h_decode = reg.histogram("demo.gen.decode_ns");
    let n_tokens = reg.counter("demo.gen.tokens");
    let s = entry.config.seq;
    let prompt = &corpus[..(s / 2).max(1)];
    let max_new = (s / 2).max(1);
    println!("generation: prompt {} tokens, up to {max_new} new",
             prompt.len());
    for (label, model) in [("FP32", fp), ("packed", packed)] {
        for (mode, sampling) in [
            ("greedy", Sampling::Greedy),
            ("top-k",
             Sampling::TopK { k: 8, temperature: 0.9 }),
        ] {
            let gc = GenConfig {
                max_new,
                sampling,
                seed: 17,
                ..GenConfig::default()
            };
            let g = generate(exec, entry, model, prompt, &gc)?;
            h_ttft.record(g.stats.ttft_ns);
            h_decode.record(g.stats.decode_ns);
            n_tokens.add(g.tokens.len() as u64);
            println!(
                "  {label:6} {mode:6} -> {:2} tokens  prefill {:6.2}ms  \
                 ttft {:6.2}ms  decode {:6.2}ms  {:7.0} tok/s  \
                 first: {:?}",
                g.tokens.len(),
                g.stats.prefill_s() * 1e3,
                g.stats.ttft_s() * 1e3,
                g.stats.decode_s() * 1e3,
                g.stats.decode_tok_per_s(),
                &g.tokens[..g.tokens.len().min(6)]
            );
        }
    }
    let agree = nsds::eval::gen::greedy_agreement(
        exec, entry, fp, packed, corpus, (s / 2).max(1), (s / 4).max(1),
        8)?;
    println!("  FP32 vs packed greedy agreement: {:.1}%", agree * 100.0);
    print!("{}", render_summary(&reg.snapshot()));
    Ok(())
}

/// Service front-end demo: the serve loop behind
/// `Client::generate_streaming` and the HTTP/SSE endpoint. Prints each
/// token as it arrives (with wall-clock arrival time), then exercises
/// `POST /v1/generate` over a raw TCP socket and fetches `/metrics`.
fn streaming_demo(entry: &ModelEntry, w: &Weights)
    -> anyhow::Result<()> {
    use std::io::{Read as _, Write as _};

    let queue = ServerQueue::new(16);
    let client = Client::new(queue.clone(), entry.config.seq);
    let serve_handle = {
        let queue = queue.clone();
        let entry = entry.clone();
        let w = w.clone();
        std::thread::spawn(move || {
            let exec = NativeEngine::new();
            serve(&exec, &entry, 2, ServedWeights::Dense(w), &queue)
        })
    };

    let s = entry.config.seq;
    let prompt: Vec<i32> = (0..(s / 2).max(1))
        .map(|i| (i % entry.config.vocab) as i32)
        .collect();
    let gc = GenConfig {
        max_new: (s / 4).clamp(1, 12),
        ..GenConfig::default()
    };

    println!("streaming: per-token events from the serve loop");
    let t0 = Instant::now();
    let events = client.generate_streaming(prompt.clone(), gc.clone())?;
    print!(" ");
    for ev in events {
        match ev {
            GenEvent::Token { token, .. } => {
                print!(" {token}@{:.1}ms",
                       t0.elapsed().as_secs_f64() * 1e3);
            }
            GenEvent::Done(g) => {
                println!("\n  done: {} tokens, ttft {:.2}ms, decode \
                          {:.2}ms",
                         g.tokens.len(), g.stats.ttft_s() * 1e3,
                         g.stats.decode_s() * 1e3);
            }
            GenEvent::Failed(e) => println!("\n  failed: {e}"),
        }
    }

    // The same request over HTTP: one SSE frame per token.
    let mut http = HttpServer::bind("127.0.0.1:0", client.clone(),
                                    queue.clone())?;
    let body = format!(r#"{{"prompt": {:?}, "max_new": {}}}"#,
                       prompt, gc.max_new);
    let mut sock = std::net::TcpStream::connect(http.addr())?;
    write!(sock, "POST /v1/generate HTTP/1.1\r\nHost: demo\r\n\
                  Content-Length: {}\r\n\r\n{body}", body.len())?;
    let mut resp = String::new();
    sock.read_to_string(&mut resp)?;
    let sse = resp.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    let frames = parse_sse(sse).map_err(|e| anyhow::anyhow!(e))?;
    let toks = frames.iter().filter(|(n, _)| n == "token").count();
    println!("  POST /v1/generate on {}: {} SSE frames ({toks} token \
              + terminal {})",
             http.addr(), frames.len(),
             frames.last().map(|(n, _)| n.as_str()).unwrap_or("?"));

    let mut sock = std::net::TcpStream::connect(http.addr())?;
    write!(sock, "GET /metrics HTTP/1.1\r\nHost: demo\r\n\r\n")?;
    let mut resp = String::new();
    sock.read_to_string(&mut resp)?;
    let json = resp.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    let snap = snapshot_from_json(&Json::parse(json)
            .map_err(|e| anyhow::anyhow!(e))?)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("  GET /metrics: {} counters, {} histograms — served {} \
              generations, {} tokens, {} cancelled",
             snap.counters.len(), snap.histograms.len(),
             queue.gen_stats().0, queue.gen_stats().1,
             queue.gen_cancelled());

    client.stop();
    serve_handle.join().unwrap()?;
    http.shutdown();
    Ok(())
}

/// Artifact-less mode: synthetic llama-s shape, native engine only.
fn synthetic_main(n_requests: usize) -> anyhow::Result<()> {
    let cfg = ModelConfig::llama_s_synth();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(99);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let bits: Vec<u8> = (0..cfg.n_layers)
        .map(|l| if l % 2 == 0 { 4 } else { 2 })
        .collect();
    let qm = QuantizedModel::quantize(
        &cfg, &fp, &bits, nsds::quant::DEFAULT_GROUP, Backend::Hqq, None,
        nsds::util::pool::default_workers());
    let exec = NativeEngine::new();
    let corpus: Vec<i32> = (0..4 * cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();

    println!("serving {} (synthetic, no artifacts), seq={}, \
              {n_requests} forwards/variant", cfg.name, cfg.seq);
    let toks: Vec<i32> = corpus[..cfg.seq].to_vec();
    for (label, model) in [("FP32", ModelRef::Dense(&fp)),
                           ("packed-2/4", ModelRef::Packed(&qm))] {
        let mut lat = Vec::with_capacity(n_requests);
        for _ in 0..n_requests {
            let t0 = Instant::now();
            std::hint::black_box(model.forward(&exec, &entry, &toks, 1)?);
            lat.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        lat.sort_by(|a, b| a.total_cmp(b));
        println!("  {label:12} fwd p50 {:7.2}ms  p95 {:7.2}ms",
                 percentile(&lat, 0.5), percentile(&lat, 0.95));
    }
    generation_demo(&exec, &entry, ModelRef::Dense(&fp),
                    ModelRef::Packed(&qm), &corpus)?;
    streaming_demo(&entry, &fp)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("llama-s");
    let n_requests: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(40);

    if !nsds::runtime::Manifest::default_dir()
        .join("manifest.json")
        .exists()
    {
        println!("no artifacts/manifest.json — synthetic serving demo \
                  (run `make artifacts` for the trained zoo)");
        return synthetic_main(n_requests);
    }

    use nsds::baselines::Method;
    use nsds::coordinator::Pipeline;
    use nsds::sensitivity::Ablation;

    let p = Pipeline::new()?;
    let entry = p.entry(model)?;
    let b = p.man.eval_batch;
    let s = entry.config.seq;
    let corpora = nsds::eval::ppl::load_corpora(&p.man)?;

    let fp = p.weights(model)?;
    let bits_nsds = p.allocate(Method::Nsds(Ablation::Full), model, 3.0)?;
    let q3 = p.quantize(model, &bits_nsds, Backend::Hqq)?;
    let q2 = p.quantize(model, &vec![2u8; entry.config.n_layers],
                        Backend::Hqq)?;
    let q3_packed = p.quantize_packed(model, &bits_nsds, Backend::Hqq)?;

    // Weight memory if served packed (codes + group metadata).
    let mem = |bits: &[u8]| -> usize {
        let mut total = 0usize;
        for (l, &bl) in bits.iter().enumerate() {
            for name in nsds::model::QUANT_WEIGHTS {
                let m = fp.layer_matrix(name, l);
                let g = nsds::quant::fit_group(
                    m.rows(), nsds::quant::DEFAULT_GROUP);
                total += match bl {
                    2 | 4 => nsds::quant::pack::packed_bytes(
                        m.rows(), m.cols(), bl, g),
                    _ => m.len() * 4,
                };
            }
        }
        total
    };
    let fp_mem: usize = (0..entry.config.n_layers)
        .map(|l| {
            nsds::model::QUANT_WEIGHTS
                .iter()
                .map(|n| fp.layer_matrix(n, l).len() * 4)
                .sum::<usize>()
        })
        .sum();

    println!("serving {model} ({} params), batch={b}, seq={s}, \
              {n_requests} requests/variant", entry.params);
    // Warm-up: compile the executable once outside every timing loop.
    run_forward(p.exec(), entry, &corpora.train[..b * s], b, &fp)?;
    for (label, w, bytes) in [
        ("FP32", &fp, fp_mem),
        ("NSDS@3bit", &q3, mem(&bits_nsds)),
        ("uniform-2bit", &q2, mem(&vec![2u8; entry.config.n_layers])),
    ] {
        let mut lat = Vec::with_capacity(n_requests);
        let t_total = Instant::now();
        for r in 0..n_requests {
            let off = (r * b * s) % (corpora.train.len() - b * s);
            let chunk = &corpora.train[off..off + b * s];
            let t0 = Instant::now();
            let logits = run_forward(p.exec(), entry, chunk, b, w)?;
            std::hint::black_box(&logits);
            lat.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let total = t_total.elapsed().as_secs_f64();
        lat.sort_by(|a, b| a.total_cmp(b));
        let toks = (n_requests * b * s) as f64;
        println!(
            "  {label:12} p50 {:7.2}ms  p95 {:7.2}ms  {:8.0} tok/s  \
             block-weights {:6.1} KiB",
            percentile(&lat, 0.5), percentile(&lat, 0.95), toks / total,
            bytes as f64 / 1024.0);
    }

    // Generation runs on the native engine (the PJRT executor has no
    // decode path), serving the same weight variants.
    let native = NativeEngine::new();
    generation_demo(&native, entry, ModelRef::Dense(&fp),
                    ModelRef::Packed(&q3_packed), &corpora.wiki_like)?;
    streaming_demo(entry, &fp)
}
