//! Serving scenario: a batched request loop over the weight-swappable
//! executor — the deployment shape a quantized LLM service uses.
//!
//!   cargo run --release --example serve_quantized [model] [n_requests]
//!
//! Compares three deployed variants (FP32, NSDS@3-bit, uniform 2-bit) on
//! the same compiled forward: per-request latency percentiles, throughput
//! (tokens/s) and weight memory. Demonstrates that swapping a quantized
//! model in/out needs NO recompilation (weights are runtime inputs).

use std::time::Instant;

use nsds::baselines::Method;
use nsds::coordinator::Pipeline;
use nsds::quant::Backend;
use nsds::runtime::run_forward;
use nsds::sensitivity::Ablation;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p) as usize;
    sorted[idx]
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("llama-s");
    let n_requests: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(40);

    let p = Pipeline::new()?;
    let entry = p.entry(model)?;
    let b = p.man.eval_batch;
    let s = entry.config.seq;
    let corpora = nsds::eval::ppl::load_corpora(&p.man)?;

    let fp = p.weights(model)?;
    let bits_nsds = p.allocate(Method::Nsds(Ablation::Full), model, 3.0)?;
    let q3 = p.quantize(model, &bits_nsds, Backend::Hqq)?;
    let q2 = p.quantize(model, &vec![2u8; entry.config.n_layers],
                        Backend::Hqq)?;

    // Weight memory if served packed (codes + group metadata).
    let mem = |bits: &[u8]| -> usize {
        let mut total = 0usize;
        for (l, &bl) in bits.iter().enumerate() {
            for name in nsds::model::QUANT_WEIGHTS {
                let m = fp.layer_matrix(name, l);
                let g = nsds::quant::fit_group(
                    m.rows(), nsds::quant::DEFAULT_GROUP);
                total += match bl {
                    2 | 4 => nsds::quant::pack::packed_bytes(
                        m.rows(), m.cols(), bl, g),
                    _ => m.len() * 4,
                };
            }
        }
        total
    };
    let fp_mem: usize = (0..entry.config.n_layers)
        .map(|l| {
            nsds::model::QUANT_WEIGHTS
                .iter()
                .map(|n| fp.layer_matrix(n, l).len() * 4)
                .sum::<usize>()
        })
        .sum();

    println!("serving {model} ({} params), batch={b}, seq={s}, \
              {n_requests} requests/variant", entry.params);
    // Warm-up: compile the executable once outside every timing loop.
    run_forward(p.exec(), entry, &corpora.train[..b * s], b, &fp)?;
    for (label, w, bytes) in [
        ("FP32", &fp, fp_mem),
        ("NSDS@3bit", &q3, mem(&bits_nsds)),
        ("uniform-2bit", &q2, mem(&vec![2u8; entry.config.n_layers])),
    ] {
        let mut lat = Vec::with_capacity(n_requests);
        let t_total = Instant::now();
        for r in 0..n_requests {
            let off = (r * b * s) % (corpora.train.len() - b * s);
            let chunk = &corpora.train[off..off + b * s];
            let t0 = Instant::now();
            let logits = run_forward(p.exec(), entry, chunk, b, w)?;
            std::hint::black_box(&logits);
            lat.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let total = t_total.elapsed().as_secs_f64();
        lat.sort_by(|a, b| a.total_cmp(b));
        let toks = (n_requests * b * s) as f64;
        println!(
            "  {label:12} p50 {:7.2}ms  p95 {:7.2}ms  {:8.0} tok/s  \
             block-weights {:6.1} KiB",
            percentile(&lat, 0.5), percentile(&lat, 0.95), toks / total,
            bytes as f64 / 1024.0);
    }
    Ok(())
}
