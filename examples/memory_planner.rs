//! Deployment memory planner: given a device memory budget for weights,
//! find the highest average-bit allocation that fits and report the
//! accuracy/ppl the deployment will get.
//!
//!   cargo run --release --example memory_planner [model] [budget_kib]
//!
//! Exercises the public API end-to-end the way an integration would:
//! packed-size accounting (quant::pack), NSDS allocation, HQQ
//! quantization, and runtime evaluation.

use nsds::baselines::Method;
use nsds::coordinator::Pipeline;
use nsds::eval::EvalOptions;
use nsds::quant::{fit_group, pack::packed_bytes, Backend, DEFAULT_GROUP};
use nsds::sensitivity::Ablation;

fn packed_model_bytes(p: &Pipeline, model: &str, bits: &[u8])
    -> anyhow::Result<usize> {
    let w = p.weights(model)?;
    let mut total = 0usize;
    for (l, &bl) in bits.iter().enumerate() {
        for name in nsds::model::QUANT_WEIGHTS {
            let m = w.layer_matrix(name, l);
            let g = fit_group(m.rows(), DEFAULT_GROUP);
            total += packed_bytes(m.rows(), m.cols(), bl, g);
        }
    }
    Ok(total)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("llama-s");
    let budget_kib: f64 =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(300.0);

    let p = Pipeline::new()?;
    let entry = p.entry(model)?;
    let nl = entry.config.n_layers;
    let nsds = Method::Nsds(Ablation::Full);

    // Scan average-bit budgets from 4.0 downward until the packed model
    // fits the device budget.
    let mut chosen = None;
    for step in 0..=(2 * nl) {
        let avg = 4.0 - step as f64 * (2.0 / (2 * nl) as f64);
        let bits = p.allocate(nsds, model, avg)?;
        let bytes = packed_model_bytes(&p, model, &bits)?;
        let kib = bytes as f64 / 1024.0;
        if kib <= budget_kib {
            chosen = Some((avg, bits, kib));
            break;
        }
    }
    let Some((avg, bits, kib)) = chosen else {
        anyhow::bail!(
            "even uniform 2-bit does not fit {budget_kib} KiB");
    };
    println!("{model}: budget {budget_kib:.0} KiB -> b̄={avg:.2} \
              ({kib:.1} KiB packed)");
    println!("allocation: {bits:?}");

    let qw = p.quantize(model, &bits, Backend::Hqq)?;
    let r = p.eval(model, &qw, &EvalOptions::default())?;
    let fp = p.eval_fp(model, &EvalOptions::default())?;
    println!("deployed:  avg acc {:6.2}%  avg ppl {:7.3}", r.avg_acc(),
             r.avg_ppl());
    println!("reference: avg acc {:6.2}%  avg ppl {:7.3}  (FP32, {:.1} \
              KiB)",
             fp.avg_acc(), fp.avg_ppl(),
             entry.params as f64 * 4.0 / 1024.0);
    Ok(())
}
