//! Sensitivity cartography: rank-correlate every layer-ranking method
//! against the ground-truth damage (single-layer 2-bit ΔPPL).
//!
//!   cargo run --release --example sensitivity_map [model]
//!
//! This is the analysis behind the paper's Fig. 1 claim: numerical
//! metrics alone miss structurally expressive layers. It prints each
//! method's per-layer scores, the measured ΔPPL oracle, and Spearman
//! rank correlations method↔oracle.

use nsds::baselines::Method;
use nsds::coordinator::Pipeline;
use nsds::quant::Backend;
use nsds::sensitivity::Ablation;

/// Spearman rank correlation.
fn spearman(a: &[f64], b: &[f64]) -> f64 {
    let rank = |xs: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&i, &j| xs[i].total_cmp(&xs[j]));
        let mut r = vec![0.0; xs.len()];
        for (pos, &i) in idx.iter().enumerate() {
            r[i] = pos as f64;
        }
        r
    };
    let ra = rank(a);
    let rb = rank(b);
    let n = a.len() as f64;
    let ma = ra.iter().sum::<f64>() / n;
    let mb = rb.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        num += (ra[i] - ma) * (rb[i] - mb);
        da += (ra[i] - ma).powi(2);
        db += (rb[i] - mb).powi(2);
    }
    num / (da.sqrt() * db.sqrt()).max(1e-12)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("llama-s");
    let p = Pipeline::new()?;
    let entry = p.entry(model)?;
    let nl = entry.config.n_layers;
    let w = p.weights(model)?;
    let corpora = nsds::eval::ppl::load_corpora(&p.man)?;

    // Ground-truth oracle: ΔPPL when only layer l is quantized to 2-bit.
    println!("measuring single-layer 2-bit ΔPPL oracle ({nl} layers)...");
    let fp_ppl = nsds::eval::ppl::perplexity(
        p.exec(), &p.man, entry, &w, &corpora.wiki_like, 16)?;
    let mut oracle = Vec::with_capacity(nl);
    for l in 0..nl {
        let mut qw = w.clone();
        for name in nsds::model::QUANT_WEIGHTS {
            let m = w.layer_matrix(name, l);
            let g = nsds::quant::fit_group(m.rows(),
                                           nsds::quant::DEFAULT_GROUP);
            let q = nsds::quant::quantize_matrix(
                &m, nsds::quant::QuantSpec::new(2, g), Backend::Hqq, None);
            qw.set_layer_matrix(name, l, &q.dequantize());
        }
        let ppl = nsds::eval::ppl::perplexity(
            p.exec(), &p.man, entry, &qw, &corpora.wiki_like, 16)?;
        oracle.push(ppl - fp_ppl);
    }
    println!("oracle ΔPPL per layer: {oracle:.3?}\n");

    let methods = [
        Method::Nsds(Ablation::Full),
        Method::Nsds(Ablation::NoSe), // NV only
        Method::Nsds(Ablation::NoNv), // SE only
        Method::Mse,
        Method::Ewq,
        Method::Zd,
        Method::KurtBoost,
    ];
    println!("{:<18} {:>9}  per-layer scores", "method", "spearman");
    for m in methods {
        let s = p.scores(m, model)?;
        let rho = spearman(&s, &oracle);
        let scores: Vec<String> =
            s.iter().map(|x| format!("{x:7.3}")).collect();
        println!("{:<18} {rho:>9.3}  [{}]", m.label(), scores.join(" "));
    }
    Ok(())
}
